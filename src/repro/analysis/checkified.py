"""Checkify-instrumented variant of the chunked phase dispatch.

The solver cores are numerically silent by design: a NaN-poisoned cost
matrix rounds to garbage integers and the solve "converges" to nonsense;
a corrupted state (e.g. a buffer reused after donation — the PR-3 bug)
walks wild indices without complaint. This module mirrors
``compaction.spec_fns`` with the functional error checks of
``jax.experimental.checkify`` (nan / index / div) plus explicit
structural invariant checks per spec, so a debug run raises a useful
error at the first poisoned chunk instead of silently terminating.

Enabled through the driver: ``repro.analysis.set_debug_checks(True)`` (or
``REPRO_DEBUG_CHECKS=1``) makes ``solve_compacting`` dispatch these
functions. Differences from the production path, by construction:

  * the chunk dispatch does NOT donate the state (checkify rewrites the
    program; holding two copies in debug mode is the accepted cost);
  * every chunk ``err.throw()``s on host — one extra sync per chunk.
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
from jax.experimental import checkify

ERRORS = (checkify.user_checks | checkify.nan_checks
          | checkify.index_checks | checkify.div_checks)
# The phase loops and completion epilogues index with the sentinel value n
# ("no match") and rely on XLA's clamped gather semantics — auto
# index_checks would flag that benign idiom on every healthy chunk. The
# chunk and epilogue therefore run nan/div auto checks plus the EXPLICIT
# structural invariants below (which do catch corrupted indices:
# match_ba/match_ab must lie in [-1, n)); the rounding prologue, which has
# no sentinel gathers, gets the full auto set.
CHUNK_ERRORS = (checkify.user_checks | checkify.nan_checks
                | checkify.div_checks)


def _assignment_invariants(data, state):
    n = data["c_int"].shape[1]
    checkify.check(
        jnp.all((state.match_ba >= -1) & (state.match_ba < n)),
        "assignment matching index out of range: match_ba must lie in "
        "[-1, {n}) (corrupted state / donated-buffer reuse?)",
        n=jnp.int32(n),
    )
    m = data["c_int"].shape[0]
    checkify.check(
        jnp.all((state.match_ab >= -1) & (state.match_ab < m)),
        "assignment matching index out of range: match_ab must lie in "
        "[-1, {m}) (corrupted state / donated-buffer reuse?)",
        m=jnp.int32(m),
    )


def _ot_invariants(data, state):
    checkify.check(
        jnp.all(state.free_b >= 0) & jnp.all(state.free_a >= 0),
        "negative free mass in OT state (corrupted state / donated-buffer "
        "reuse?)",
    )
    checkify.check(
        jnp.all(state.f_hi >= 0) & jnp.all(state.f_lo >= 0),
        "negative flow in OT state (corrupted state / donated-buffer "
        "reuse?)",
    )


def _sinkhorn_invariants(data, state):
    checkify.check(
        jnp.all(jnp.isfinite(state.f)) & jnp.all(jnp.isfinite(state.g)),
        "non-finite Sinkhorn potentials (poisoned costs / corrupted "
        "state / donated-buffer reuse?)",
    )
    checkify.check(
        jnp.all(data["reg"] > 0),
        "non-positive Sinkhorn regularization (schedule corrupted?)",
    )


_INVARIANTS = {"assignment": _assignment_invariants, "ot": _ot_invariants,
               "warm_ot": _ot_invariants,
               "sinkhorn": _sinkhorn_invariants}


def _throwing(ck_fn):
    def wrapped(*args):
        err, out = ck_fn(*args)
        err.throw()
        return out
    return wrapped


def checkified_spec_fns(spec, k: int):
    """(prologue, init, chunk, conv, epilogue) mirroring
    ``compaction.spec_fns`` with checkify instrumentation on the
    prologue, chunk, and epilogue dispatches (init and the converged
    probe stay plain: they are pure shape/compare code). Same call
    signatures; the chunk does NOT donate.

    Fused specs route through their stepped base (BEFORE the cache, so
    fused and stepped share one instrumented program family): checkify
    cannot instrument the interior of a Pallas kernel (the state never
    surfaces between phases), and the fused trajectory is bit-identical
    to the stepped one (tests/test_fused_phase.py), so the stepped chunk
    checks exactly the states the fused kernel would produce."""
    return _checkified_spec_fns(getattr(spec, "stepped", spec), k)


@lru_cache(maxsize=None)
def _checkified_spec_fns(spec, k: int):
    from ..core.compaction import spec_fns

    _, init, _, conv, _ = spec_fns(spec, k)
    invariants = _INVARIANTS[spec.name]

    # vmap OUTSIDE checkify everywhere: checkify cannot rewrite a batched
    # while-loop (checkify-of-vmap-of-while is unsupported, and the
    # epilogues run completion loops too), but vmap-of-checkify batches
    # the error value per lane and ``throw()`` reports the first failed
    # lane's message.
    ck_prologue = jax.jit(lambda ops: jax.vmap(
        checkify.checkify(spec.prologue, errors=ERRORS))(ops))

    def one(d, s):
        invariants(d, s)
        return spec.run_phases(d, s, k)

    ck_one = checkify.checkify(one, errors=CHUNK_ERRORS)
    ck_chunk = jax.jit(lambda data, state: jax.vmap(ck_one)(data, state))
    ck_epilogue = jax.jit(lambda ctx, state: jax.vmap(
        checkify.checkify(spec.epilogue, errors=CHUNK_ERRORS))(ctx, state))

    return (_throwing(ck_prologue), init, _throwing(ck_chunk), conv,
            _throwing(ck_epilogue))
