"""Backend selection + per-backend XLA tuning flags, applied BEFORE the
first jax device touch.

``set_platform`` pins ``jax_platform_name`` and, for GPU, installs the
latency-hiding / async-stream XLA flags the fused phase kernels are
tuned against (the paper's GPU implementation overlaps the propose/push
sweeps with collective traffic; XLA only does the equivalent when the
latency-hiding scheduler and high-priority async streams are enabled).
Like the mesh builders in ``launch/mesh.py``, everything here is a
FUNCTION — importing this module never touches jax backend state, and
``set_platform`` must run before the first computation (jax initializes
its backend once, on first use; ``jax.config.update`` after that point
is silently ignored for an already-initialized backend).

The flag set mirrors jax's own GPU performance guidance; `gpu_flags()`
exposes it separately so launchers that manage ``XLA_FLAGS`` themselves
(SLURM prologs, container entrypoints) can merge rather than overwrite.
"""
from __future__ import annotations

import os

import jax

_GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true",
    "--xla_gpu_triton_gemm_any=True",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

_PLATFORMS = ("cpu", "gpu", "tpu")


def gpu_flags() -> str:
    """The GPU XLA flag string, for launchers that merge ``XLA_FLAGS``
    themselves instead of calling :func:`set_platform`."""
    return " ".join(_GPU_XLA_FLAGS)


def set_platform(platform: str = "cpu") -> None:
    """Pin the jax backend to ``platform`` ('cpu' | 'gpu' | 'tpu') and,
    on GPU, install the latency-hiding/async-stream XLA flags.

    Call this before the first jax computation of the process; existing
    ``XLA_FLAGS`` content is preserved (our flags are appended, so an
    operator-set flag wins under XLA's last-one-wins parsing only if it
    comes later — we therefore skip any flag the environment already
    sets)."""
    if platform not in _PLATFORMS:
        raise ValueError(
            f"unknown platform {platform!r}; expected one of {_PLATFORMS}")
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        existing = os.environ.get("XLA_FLAGS", "")
        keep = [f for f in _GPU_XLA_FLAGS
                if f.split("=")[0] not in existing]
        os.environ["XLA_FLAGS"] = " ".join(
            ([existing] if existing else []) + keep)
