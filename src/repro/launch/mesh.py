"""Production mesh builders. FUNCTIONS (not module constants) so importing
never touches jax device state.

``_make_mesh`` papers over jax API drift: ``axis_types=`` (and
``jax.sharding.AxisType``) only exist on newer jax; older releases build
the same Auto-axis mesh without the kwarg.
"""
from __future__ import annotations

import inspect
import math

import jax


def _make_mesh(shape, axes, devices):
    kwargs = {}
    if (hasattr(jax.sharding, "AxisType")
            and "axis_types" in inspect.signature(jax.make_mesh).parameters):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(jax.devices())} - run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return _make_mesh(shape, axes, devices)


def make_small_mesh(shape=(2, 4), axes=("data", "model")):
    """CI-scale mesh for dry-run smoke tests (8 forced host devices)."""
    n = math.prod(shape)
    return _make_mesh(shape, axes, jax.devices()[:n])


def largest_pow2_at_most(x: int) -> int:
    """Largest power of two <= max(x, 1)."""
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def make_batch_mesh(n_devices: int | None = None, axis: str = "data"):
    """1-D mesh for batch-axis sharding (core/distributed.py).

    Uses the largest power-of-two prefix of the host's devices: the
    distributed compacting driver keeps batch buckets divisible by the
    device count, and its power-of-two bucket descent only stays divisible
    when the device count is itself a power of two."""
    avail = len(jax.devices())
    n = avail if n_devices is None else min(int(n_devices), avail)
    p = largest_pow2_at_most(n)
    return _make_mesh((p,), (axis,), jax.devices()[:p])
