"""Production mesh builders. A FUNCTION (not module constant) so importing
never touches jax device state."""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have "
            f"{len(jax.devices())} - run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(
        shape, axes, devices=devices,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_small_mesh(shape=(2, 4), axes=("data", "model")):
    """CI-scale mesh for dry-run smoke tests (8 forced host devices)."""
    n = math.prod(shape)
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
