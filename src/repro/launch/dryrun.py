import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first init, and the production mesh needs 512 placeholder devices.

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, SHAPES, SMOKE_SHAPES, \
    shape_applicable, reduced
from repro.launch.mesh import make_production_mesh, make_small_mesh
from repro.models import model as M
from repro.models import sharding
from repro.roofline.analysis import roofline_terms, model_flops
from repro.train.train_step import make_train_step


def _sds(tree, spec_tree, mesh):
    def one(leaf, spec):
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)
        )

    return jax.tree.map(one, tree, spec_tree)


def _batch_axis(n: int, mesh) -> Any:
    dp = sharding._STATE["dp"]
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return "dp" if n % size == 0 else None


def batch_specs(cfg, shape, kind, mesh):
    specs = M.input_specs(cfg, shape.seq_len, shape.global_batch, kind)
    ba = _batch_axis(shape.global_batch, mesh)

    def one(k, leaf):
        if leaf.ndim == 0:
            spec = P()
        else:
            spec = sharding.pspec(ba, *([None] * (leaf.ndim - 1)))
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec)
        )

    return {k: one(k, v) for k, v in specs.items()}


def opt_pspecs(cfg, params_abs, opt_abs):
    """Optimizer-state specs mirror the param specs; Adafactor's factored
    leaves inherit truncated specs (vr: drop last dim; vc: drop 2nd-last)."""
    pspecs = sharding.param_pspecs(params_abs)
    leaves, treedef = jax.tree_util.tree_flatten(params_abs)
    spec_leaves = treedef.flatten_up_to(pspecs)

    def like_params(tree):
        return treedef.unflatten(spec_leaves)

    if cfg.optimizer == "adamw":
        m = like_params(opt_abs.m)
        v = like_params(opt_abs.v)
    else:
        m = None
        v_leaves = []
        for spec, pleaf in zip(spec_leaves, leaves):
            parts = list(spec)
            parts += [None] * (len(pleaf.shape) - len(parts))
            if len(pleaf.shape) >= 2:
                vr = P(*parts[:-1])
                vc = P(*(parts[:-2] + parts[-1:]))
                v_leaves.append((vr, vc))
            else:
                v_leaves.append((P(*parts),))
        v = treedef.unflatten(v_leaves)
    return type(opt_abs)(step=P(), m=m, v=v, comp_err=None)


def _cache_spec(path, leaf, mesh, batch):
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    ba = _batch_axis(batch, mesh)
    nd = leaf.ndim
    if name in ("self_k", "self_v", "cross_k", "cross_v"):
        # (period, B, S, KvH, Dh): flash-decode style - sequence over 'tp'
        spec = sharding.pspec(None, ba, "tp", None, None)
    elif nd == 5:   # ssm_state (period, B, H, P, N)
        spec = sharding.pspec(None, ba, "tp", None, None)
    elif nd == 4:   # conv states (period, B, 3, C)
        tp = "tp" if leaf.shape[-1] % mesh.shape[sharding._STATE["tp"]] == 0 \
            and leaf.shape[-1] >= 1024 else None
        spec = sharding.pspec(None, ba, None, tp)
    else:
        spec = sharding.pspec(*([None] * nd))
    return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                sharding=NamedSharding(mesh, spec))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               router: Optional[str] = None, small: bool = False,
               smoke: bool = False, unroll: bool = True,
               seq_shard: bool = False, fast_decode: bool = False,
               parallel_block: bool = False):
    """Returns (lowered, meta) for one (arch x shape x mesh) cell."""
    cfg = ARCHS[arch]
    if smoke:
        cfg = reduced(cfg)
    if router:
        cfg = cfg.with_(router=router)
    cfg = cfg.with_(scan_unroll=unroll, seq_shard=seq_shard,
                    fast_decode_math=fast_decode,
                    parallel_block=parallel_block)
    shape = (SMOKE_SHAPES if smoke else SHAPES)[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": reason}
    mesh = make_small_mesh() if small else make_production_mesh(
        multi_pod=multi_pod)
    sharding.set_mesh(mesh)
    params_abs = M.abstract_params(cfg)
    pspecs = sharding.param_pspecs(params_abs)
    params_sds = _sds(params_abs, pspecs, mesh)

    if shape.kind == "train":
        opt_init, step_fn = make_train_step(cfg)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        opt_sds = _sds(opt_abs, opt_pspecs(cfg, params_abs, opt_abs), mesh)
        batch_sds = batch_specs(cfg, shape, "train", mesh)
        lowered = step_fn.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        batch_sds = batch_specs(cfg, shape, "prefill", mesh)
        fn = jax.jit(lambda p, b: M.prefill(p, cfg, b))
        lowered = fn.lower(params_sds, batch_sds)
    elif shape.kind == "decode":
        cache_abs = jax.eval_shape(
            lambda p, b: M.prefill(p, cfg, b)[0],
            params_abs,
            M.input_specs(cfg, shape.seq_len, shape.global_batch, "prefill"),
        )
        cache_sds = jax.tree_util.tree_map_with_path(
            lambda pth, l: _cache_spec(pth, l, mesh, shape.global_batch),
            cache_abs,
        )
        ba = _batch_axis(shape.global_batch, mesh)
        tok_sds = jax.ShapeDtypeStruct(
            (shape.global_batch, 1), jnp.int32,
            sharding=NamedSharding(mesh, sharding.pspec(ba, None)),
        )
        pos_sds = jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P()))
        fn = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, cfg, c, t, pos),
            donate_argnums=(1,),
        )
        lowered = fn.lower(params_sds, cache_sds, tok_sds, pos_sds)
    else:
        raise ValueError(shape.kind)
    return lowered, {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "router": cfg.router,
        "n_chips": int(np.prod(list(mesh.shape.values()))),
        "mesh": dict(mesh.shape), "cfg_shape": shape,
        "cfg": cfg,
    }


def run_cell(arch, shape_name, *, multi_pod=False, router=None, small=False,
             smoke=False, save_hlo: Optional[str] = None, unroll=True,
             seq_shard=False, fast_decode=False,
             parallel_block=False) -> Dict:
    t0 = time.time()
    try:
        lowered, meta = lower_cell(
            arch, shape_name, multi_pod=multi_pod, router=router,
            small=small, smoke=smoke, unroll=unroll, seq_shard=seq_shard,
            fast_decode=fast_decode, parallel_block=parallel_block,
        )
        if lowered is None:
            return {"arch": arch, "shape": shape_name,
                    "multi_pod": multi_pod, "ok": True, **meta}
        compiled = lowered.compile()
        from repro.compat import cost_analysis_dict

        cost = cost_analysis_dict(compiled)
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        tp_size = meta["mesh"].get("model", 16)
        terms = roofline_terms(cost, hlo)
        mf = model_flops(meta["cfg"], meta["cfg_shape"], meta["n_chips"])
        result = {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "router": meta["router"], "ok": True,
            "n_chips": meta["n_chips"], "mesh": meta["mesh"],
            "kind": meta["kind"],
            "compile_s": round(time.time() - t0, 1),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_device_gb": round(
                    (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
                    / 2**30, 3),
            },
            "unroll": unroll, "seq_shard": seq_shard,
            "roofline": {k: v for k, v in terms.items()},
            "model_flops": mf,
            "hlo_flops_ratio": (
                mf["model_flops_per_device"]
                / max(terms["flops_per_device"], 1.0)
            ),
        }
        if save_hlo:
            os.makedirs(save_hlo, exist_ok=True)
            tag = f"{arch}__{shape_name}__{'mp' if multi_pod else 'sp'}"
            with open(os.path.join(save_hlo, tag + ".collectives.txt"),
                      "w") as f:
                for line in hlo.splitlines():
                    if any(op in line for op in (
                            "all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")):
                        f.write(line.strip()[:400] + "\n")
        return result
    except Exception as e:
        return {
            "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
            "ok": False, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
            "compile_s": round(time.time() - t0, 1),
        }


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            for mp in (False, True):
                yield arch, shape, mp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--router", default=None)
    ap.add_argument("--small", action="store_true",
                    help="2x4 CI mesh instead of production mesh")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced arch config + tiny shapes")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell in "
                         "subprocesses and aggregate")
    ap.add_argument("--only-mesh", choices=["sp", "mp"], default=None)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--no-unroll", action="store_true",
                    help="keep lax.scan over layers (faster compile; XLA "
                         "costs the body once -> flops undercounted)")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel residual stream (hillclimb)")
    ap.add_argument("--fast-decode", action="store_true",
                    help="bf16 cache reads w/ fp32 accumulation (hillclimb)")
    ap.add_argument("--parallel-block", action="store_true",
                    help="PaLM-style parallel attn+FFN block (hillclimb)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        for arch, shape, mp in all_cells():
            if args.only_mesh == "sp" and mp:
                continue
            if args.only_mesh == "mp" and not mp:
                continue
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            if args.no_unroll:
                cmd.append("--no-unroll")
            if args.save_hlo:
                cmd += ["--save-hlo", args.save_hlo]
            print(f"[dryrun] {tag} ...", flush=True)
            subprocess.run(cmd, check=False)
        return

    res = run_cell(
        args.arch, args.shape, multi_pod=args.multi_pod, router=args.router,
        small=args.small, smoke=args.smoke, save_hlo=args.save_hlo,
        unroll=not args.no_unroll, seq_shard=args.seq_shard,
        fast_decode=args.fast_decode, parallel_block=args.parallel_block,
    )
    tag = f"{args.arch}__{args.shape}__{'mp' if args.multi_pod else 'sp'}"
    if args.router:
        tag += f"__{args.router}"
    if args.smoke or args.small:
        tag += "__smoke"
    if args.tag:
        tag += f"__{args.tag}"
    path = os.path.join(args.out, tag + ".json")
    with open(path, "w") as f:
        json.dump(res, f, indent=1, default=str)
    print(json.dumps(
        {k: res.get(k) for k in ("arch", "shape", "multi_pod", "ok",
                                 "skipped", "error", "compile_s")},
        default=str))
    if res.get("ok") and "roofline" in res:
        r = res["roofline"]
        print(f"  terms: compute={r['t_compute_s']:.4f}s "
              f"memory={r['t_memory_s']:.4f}s "
              f"collective={r['t_collective_s']:.4f}s "
              f"dominant={r['dominant']} "
              f"roofline_frac={r['roofline_fraction']:.3f}")
        print(f"  mem/device: {res['memory']['peak_per_device_gb']} GiB; "
              f"model/HLO flops ratio: {res['hlo_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
