"""Jitted training step: value_and_grad -> clip -> optimizer, with optional
microbatch gradient accumulation (lax.scan over batch slices; under pjit the
per-microbatch reduce-scatter overlaps the next microbatch's compute via XLA
latency hiding) and optional int8 error-feedback gradient compression for
the cross-pod reduction."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.optim.optimizer import (
    clip_by_global_norm, cosine_schedule, make_optimizer,
)


def make_loss(cfg):
    def loss_fn(params, batch):
        return M.loss_fn(params, cfg, batch)

    return loss_fn


def make_train_step(cfg, *, lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, grad_accum: int = 1,
                    max_grad_norm: float = 1.0, donate: bool = True):
    """Returns (init_fn, step_fn). step_fn: (params, opt, batch) ->
    (params, opt, metrics)."""
    lr_fn = cosine_schedule(lr, warmup, total_steps)
    opt_init, opt_step = make_optimizer(cfg.optimizer, lr_fn)
    loss_fn = make_loss(cfg)

    def grads_of(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(carry, mb):
            acc_loss, acc_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_loss + l,
                    jax.tree.map(jnp.add, acc_g, g)), None

        micro_batches = jax.tree.map(
            lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                + x.shape[1:]),
            batch,
        )
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (loss, grads), _ = jax.lax.scan(
            micro, (jnp.float32(0.0), zeros), micro_batches
        )
        inv = 1.0 / grad_accum
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step_fn(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt_step(params, grads, opt_state)
        return params, opt_state, {
            "loss": loss,
            "grad_norm": gnorm,
            "lr": lr_fn(opt_state.step - 1),
        }

    jit_kwargs: Dict[str, Any] = {}
    if donate:
        jit_kwargs["donate_argnums"] = (0, 1)
    return opt_init, jax.jit(step_fn, **jit_kwargs)
