"""Fault-tolerant training loop.

- checkpoint/restart: atomic CRC-verified checkpoints every `ckpt_every`
  steps (async write); on start, resumes from the newest valid checkpoint -
  a SIGKILL mid-run loses at most `ckpt_every` steps and never corrupts
  state.
- deterministic data: batches are a pure function of (seed, step); resume
  replays the exact stream (see data/pipeline.py).
- straggler watchdog: per-step wall-time EWMA; steps slower than
  `straggler_factor` x EWMA are counted and logged (at fleet scale this is
  the signal used to evict/replace a slow host; here it feeds metrics).
- elastic restore: pass `shardings` built on the *current* mesh - the
  checkpoint stores full logical tensors, so restarting on a different
  device count re-shards transparently (tested in tests/test_trainer.py).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Optional

import jax

from repro.checkpoint import checkpointing as ckpt
from repro.data.pipeline import synthetic_batch
from repro.models import model as M
from .train_step import make_train_step


class Trainer:
    def __init__(self, cfg, workdir: str, *, seq_len: int = 128,
                 batch_size: int = 8, lr: float = 3e-4, seed: int = 0,
                 ckpt_every: int = 20, grad_accum: int = 1,
                 total_steps: int = 10_000, warmup: int = 100,
                 shardings: Any = None,
                 straggler_factor: float = 3.0):
        self.cfg = cfg
        self.workdir = workdir
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.seed = seed
        self.ckpt_every = ckpt_every
        self.shardings = shardings
        self.straggler_factor = straggler_factor
        self.metrics_log = os.path.join(workdir, "metrics.jsonl")
        os.makedirs(workdir, exist_ok=True)

        opt_init, self.step_fn = make_train_step(
            cfg, lr=lr, grad_accum=grad_accum, total_steps=total_steps,
            warmup=warmup,
        )
        start = ckpt.latest_step(os.path.join(workdir, "ckpt"))
        if start is None:
            params = M.init_params(cfg, jax.random.key(seed))
            opt_state = opt_init(params)
            self.step = 0
        else:
            params = M.init_params(cfg, jax.random.key(seed))
            opt_state = opt_init(params)
            like = {"params": params, "opt": opt_state}
            restored = ckpt.restore(
                os.path.join(workdir, "ckpt"), start, like,
                shardings=self.shardings,
            )
            params, opt_state = restored["params"], restored["opt"]
            self.step = start
        self.params = params
        self.opt_state = opt_state
        self._ewma: Optional[float] = None
        self.straggler_events = 0
        self._pending_save = None

    def _checkpoint(self):
        if self._pending_save is not None:
            self._pending_save.join()
        self._pending_save = ckpt.save(
            os.path.join(self.workdir, "ckpt"), self.step,
            {"params": self.params, "opt": self.opt_state}, async_=True,
        )

    def run(self, num_steps: int, log_every: int = 10):
        history = []
        for _ in range(num_steps):
            batch_np = synthetic_batch(
                self.cfg, self.seq_len, self.batch_size,
                seed=self.seed, step=self.step,
            )
            batch = jax.tree.map(jax.numpy.asarray, batch_np)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            loss = float(metrics["loss"])  # sync point
            dt = time.perf_counter() - t0
            if self._ewma is None:
                self._ewma = dt
            else:
                if dt > self.straggler_factor * self._ewma:
                    self.straggler_events += 1
                self._ewma = 0.9 * self._ewma + 0.1 * dt
            self.step += 1
            rec = {"step": self.step, "loss": loss, "time_s": dt,
                   "grad_norm": float(metrics["grad_norm"]),
                   "stragglers": self.straggler_events}
            history.append(rec)
            with open(self.metrics_log, "a") as f:
                f.write(json.dumps(rec) + "\n")
            if self.step % self.ckpt_every == 0:
                self._checkpoint()
        self._checkpoint()
        if self._pending_save is not None:
            self._pending_save.join()
            self._pending_save = None
        return history
