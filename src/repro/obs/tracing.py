"""Hierarchical spans over the monotonic clock, emitted as events.

A ``Span`` is one timed region (``t_start``/``t_end`` from
``metrics.now``) with a name, a trace id (per-request or per-bucket),
its own span id, and an optional parent span id — enough to rebuild the
tree submit → admission → collate → bucket dispatch → per-chunk solve →
artifact fetch from a flat event stream.  Spans are emitted ONCE, on
``end()``, as a single ``"span"`` event carrying both timestamps; there
is no partial state to lock.

``Tracer`` is the handle threaded through the serving stack: it holds
the registry (for sink fan-out), default trace/parent ids, and default
attributes.  ``bind()`` derives a child tracer with different defaults —
this is how the chunked drivers' per-chunk events get parented under the
dispatch's solve span without the drivers knowing about scheduling.

Thread-safety: span ids come from ``itertools.count`` (atomic in
CPython); a ``Span`` is only ever mutated by the thread that ends it;
``Tracer`` itself is immutable after construction.  Scan-exempt for
those reasons.
"""
from __future__ import annotations

import itertools
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional

from .metrics import MetricsRegistry, now

_ids = itertools.count(1)


def new_id(prefix: str) -> str:
    """A process-unique id, e.g. ``new_id('req') -> 'req-17'``."""
    return f"{prefix}-{next(_ids)}"


class Span:
    """One timed region.  Emitted as a ``"span"`` event on ``end()``."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t_start",
                 "t_end", "attrs", "_tracer")

    def __init__(self, name: str, trace_id: str, span_id: int,
                 parent_id: Optional[int], attrs: Dict[str, Any],
                 tracer: "Tracer") -> None:
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = now()
        self.t_end: Optional[float] = None
        self.attrs = attrs
        self._tracer = tracer

    def end(self, **attrs: Any) -> None:
        if self.t_end is not None:  # idempotent: first end wins
            return
        self.t_end = now()
        payload: Dict[str, Any] = {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "dur_s": self.t_end - self.t_start,
        }
        payload.update(self.attrs)
        payload.update(attrs)
        self._tracer.registry.emit("span", payload)

    def child(self, tracer_attrs: bool = False) -> "Tracer":
        """A tracer whose spans/events are parented under this span."""
        return self._tracer.bind(trace_id=self.trace_id,
                                 parent=self.span_id)


class Tracer:
    """Factory for spans and structured events over one registry."""

    __slots__ = ("registry", "trace_id", "parent_id", "attrs")

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 trace_id: Optional[str] = None,
                 parent_id: Optional[int] = None,
                 **attrs: Any) -> None:
        self.registry = registry if registry is not None else MetricsRegistry()
        self.trace_id = trace_id
        self.parent_id = parent_id
        self.attrs = attrs

    def bind(self, trace_id: Optional[str] = None,
             parent: Optional[int] = None, **attrs: Any) -> "Tracer":
        """Derive a tracer with new default trace/parent ids and attrs."""
        merged = dict(self.attrs)
        merged.update(attrs)
        return Tracer(self.registry,
                      trace_id=trace_id if trace_id is not None
                      else self.trace_id,
                      parent_id=parent if parent is not None
                      else self.parent_id,
                      **merged)

    def start(self, name: str, trace_id: Optional[str] = None,
              parent: Optional[int] = None, **attrs: Any) -> Span:
        """Begin a span; the caller must ``end()`` it (possibly on
        another thread — spans routinely cross the submit/dispatch
        thread boundary)."""
        merged = dict(self.attrs)
        merged.update(attrs)
        tid = trace_id if trace_id is not None else self.trace_id
        if tid is None:
            tid = new_id("trace")
        pid = parent if parent is not None else self.parent_id
        return Span(name, tid, next(_ids), pid, merged, self)

    @contextmanager
    def span(self, name: str, trace_id: Optional[str] = None,
             parent: Optional[int] = None, **attrs: Any) -> Iterator[Span]:
        s = self.start(name, trace_id=trace_id, parent=parent, **attrs)
        try:
            yield s
        except BaseException as e:
            s.end(error=type(e).__name__)
            raise
        else:
            s.end()

    def event(self, kind: str, **attrs: Any) -> None:
        """Emit a point-in-time structured event."""
        payload: Dict[str, Any] = {"t": now()}
        if self.trace_id is not None:
            payload["trace_id"] = self.trace_id
        if self.parent_id is not None:
            payload["parent_id"] = self.parent_id
        payload.update(self.attrs)
        payload.update(attrs)
        self.registry.emit(kind, payload)


def span_tree(events, trace_id: Optional[str] = None) -> str:
    """Render ``"span"`` events (dicts) as an indented tree — demo/debug
    helper used by quickstart section 14."""
    spans = [e for e in events
             if e.get("name") is not None and "span_id" in e
             and (trace_id is None or e.get("trace_id") == trace_id)]
    by_parent: Dict[Optional[int], list] = {}
    ids = {s["span_id"] for s in spans}
    for s in spans:
        p = s.get("parent_id")
        by_parent.setdefault(p if p in ids else None, []).append(s)
    lines: list = []

    def walk(parent: Optional[int], depth: int) -> None:
        for s in sorted(by_parent.get(parent, []),
                        key=lambda x: x["t_start"]):
            lines.append("  " * depth
                         + f"{s['name']} [{s['trace_id']}] "
                         f"{1e3 * s['dur_s']:.2f} ms")
            walk(s["span_id"], depth + 1)

    walk(None, 0)
    return "\n".join(lines)
