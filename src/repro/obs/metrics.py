"""Pluggable metrics: sinks, instruments, and the aggregating registry.

Design constraints (enforced by ``repro.analysis``):

* **One clock.** ``now()`` is the monotonic time base shared by spans,
  deadlines, and wait/solve stats across the serving stack.  The chunked
  drivers compare ``deadline`` against the same clock, so a deadline
  computed from ``now()`` in the scheduler means the same instant inside
  ``core/compaction.py``.

* **Lock-free on the hot path.**  ``Counter.add`` / ``Gauge.set`` /
  ``Histogram.observe`` never take a lock: counters and histograms keep
  one cell per writer thread (keyed by ``threading.get_ident()``) so the
  only mutations are single-key updates of the writer's own cell, which
  are safe under the GIL.  Aggregation (``value`` / ``aggregate``) sums a
  point-in-time copy of the cells.  The registry's lock guards only
  instrument *creation* and the sink list rebind — never an observation.

* **Sinks own their thread-safety.**  The registry fans observations out
  to an immutable tuple (``_sinks_ro``) that is only ever rebound whole
  (atomic attribute read, no lock on the read side).  ``JSONLSink``
  serializes writes under its own lock; ``InMemorySink`` relies on
  ``deque.append`` atomicity; ``LoggingSink`` rides the logging module's
  per-handler locks.

The lock-discipline scan in ``repro.analysis.locks`` covers
``MetricsRegistry`` (``_instruments`` under ``_lock``), ``JSONLSink``
(``_fh`` under ``_lock``) and ``History`` (``_items`` under ``_lock``);
the lock-free instruments are recorded as documented exemptions.
"""
from __future__ import annotations

import json
import logging
import threading
import time
from bisect import bisect_left
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Protocol, Sequence, Tuple, runtime_checkable

#: THE monotonic clock for the serving stack.  Spans, request deadlines,
#: wait/solve accounting, and the chunk-loop deadline checks all read
#: this one function so their timestamps are mutually comparable.
now = time.monotonic

_DEFAULT_LATENCY_BOUNDS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0, 30.0,
)


def jsonable(obj: Any) -> Any:
    """Best-effort conversion to something ``json.dumps`` accepts."""
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple, deque)):
        return [jsonable(v) for v in obj]
    item = getattr(obj, "item", None)
    if callable(item):
        try:
            return jsonable(item())
        except Exception:
            pass
    tolist = getattr(obj, "tolist", None)
    if callable(tolist):
        try:
            return jsonable(tolist())
        except Exception:
            pass
    return str(obj)


@runtime_checkable
class MetricsSink(Protocol):
    """Receiver for streamed observations and structured events.

    Implementations MUST be safe to call from multiple threads: the
    scheduler's collate and dispatch workers both emit.
    """

    def counter(self, name: str, value: float, t: float) -> None: ...

    def gauge(self, name: str, value: float, t: float) -> None: ...

    def histogram(self, name: str, value: float,
                  bounds: Tuple[float, ...], t: float) -> None: ...

    def event(self, kind: str, payload: Dict[str, Any]) -> None: ...

    def close(self) -> None: ...


class NullSink:
    """A sink that drops everything (overhead-measurement control)."""

    def counter(self, name: str, value: float, t: float) -> None:
        pass

    def gauge(self, name: str, value: float, t: float) -> None:
        pass

    def histogram(self, name: str, value: float,
                  bounds: Tuple[float, ...], t: float) -> None:
        pass

    def event(self, kind: str, payload: Dict[str, Any]) -> None:
        pass

    def close(self) -> None:
        pass


class InMemorySink:
    """Record every observation in memory; query helpers for tests.

    ``deque.append`` is atomic under the GIL, so concurrent emitters need
    no lock; query helpers snapshot the deque with ``list()`` (a single
    C-level call, so it cannot interleave with an append) before
    filtering.  Exempt from the lock-discipline scan for that reason.
    """

    def __init__(self) -> None:
        self.records: deque = deque()

    def counter(self, name: str, value: float, t: float) -> None:
        self.records.append(("counter", name, value, t))

    def gauge(self, name: str, value: float, t: float) -> None:
        self.records.append(("gauge", name, value, t))

    def histogram(self, name: str, value: float,
                  bounds: Tuple[float, ...], t: float) -> None:
        self.records.append(("histogram", name, value, t))

    def event(self, kind: str, payload: Dict[str, Any]) -> None:
        self.records.append(("event", kind, payload, payload.get("t")))

    def close(self) -> None:
        pass

    # -- query helpers (tests / demos) ---------------------------------
    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        out = []
        for ch, k, payload, _t in list(self.records):
            if ch == "event" and (kind is None or k == kind):
                rec = dict(payload)
                rec.setdefault("kind", k)
                out.append(rec)
        return out

    def count(self, kind: str) -> int:
        return len(self.events(kind))

    def counter_total(self, name: str) -> float:
        return sum(v for ch, n, v, _t in list(self.records)
                   if ch == "counter" and n == name)

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [e for e in self.events("span")
                if name is None or e.get("name") == name]


class JSONLSink:
    """Append one JSON object per observation to a file.

    Serialization happens outside the lock; only the file write is
    serialized (``_fh`` is guarded by ``_lock`` — covered by the
    lock-discipline scan).
    """

    def __init__(self, path: str) -> None:
        self.path = str(path)
        self._lock = threading.Lock()
        self._fh = open(self.path, "a", encoding="utf-8")

    def _write(self, obj: Dict[str, Any]) -> None:
        line = json.dumps(jsonable(obj), separators=(",", ":")) + "\n"
        with self._lock:
            if self._fh is not None:
                self._fh.write(line)

    def counter(self, name: str, value: float, t: float) -> None:
        self._write({"kind": "counter", "name": name, "value": value, "t": t})

    def gauge(self, name: str, value: float, t: float) -> None:
        self._write({"kind": "gauge", "name": name, "value": value, "t": t})

    def histogram(self, name: str, value: float,
                  bounds: Tuple[float, ...], t: float) -> None:
        self._write({"kind": "histogram", "name": name, "value": value,
                     "t": t})

    def event(self, kind: str, payload: Dict[str, Any]) -> None:
        self._write({"kind": "event", "event": kind, "data": payload})

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None


class LoggingSink:
    """Forward observations to the stdlib ``logging`` module.

    The logging module serializes handler writes internally, so this
    sink carries no state of its own (scan-exempt).
    """

    def __init__(self, logger: Optional[logging.Logger] = None,
                 level: int = logging.INFO) -> None:
        self.logger = logger or logging.getLogger("repro.obs")
        self.level = level

    def counter(self, name: str, value: float, t: float) -> None:
        self.logger.log(self.level, "counter %s +%s", name, value)

    def gauge(self, name: str, value: float, t: float) -> None:
        self.logger.log(self.level, "gauge %s=%s", name, value)

    def histogram(self, name: str, value: float,
                  bounds: Tuple[float, ...], t: float) -> None:
        self.logger.log(self.level, "histogram %s<-%s", name, value)

    def event(self, kind: str, payload: Dict[str, Any]) -> None:
        self.logger.log(self.level, "event %s %s", kind, jsonable(payload))

    def close(self) -> None:
        pass


class Counter:
    """Monotonic counter with one cell per writer thread (lock-free add)."""

    __slots__ = ("name", "_reg", "_cells")

    def __init__(self, name: str, reg: "MetricsRegistry") -> None:
        self.name = name
        self._reg = reg
        self._cells: Dict[int, float] = {}

    def add(self, n: float = 1) -> None:
        cells = self._cells
        tid = threading.get_ident()
        cells[tid] = cells.get(tid, 0) + n
        sinks = self._reg._sinks_ro
        if sinks:
            t = now()
            for s in sinks:
                s.counter(self.name, n, t)

    @property
    def value(self) -> float:
        return sum(self._cells.copy().values())


class Gauge:
    """Last-write-wins scalar.  ``set`` is a single attribute rebind."""

    __slots__ = ("name", "_reg", "_value")

    def __init__(self, name: str, reg: "MetricsRegistry") -> None:
        self.name = name
        self._reg = reg
        self._value: float = 0.0

    def set(self, v: float) -> None:
        self._value = v
        sinks = self._reg._sinks_ro
        if sinks:
            t = now()
            for s in sinks:
                s.gauge(self.name, v, t)

    @property
    def value(self) -> float:
        return self._value


class _HistCell:
    __slots__ = ("counts", "total", "n")

    def __init__(self, nbuckets: int) -> None:
        self.counts = [0] * nbuckets
        self.total = 0.0
        self.n = 0


class Histogram:
    """Histogram with EXPLICIT bucket upper bounds (+inf implicit).

    Per-thread cells make ``observe`` lock-free; ``aggregate`` sums a
    copy of the cell map.
    """

    __slots__ = ("name", "bounds", "_reg", "_cells")

    def __init__(self, name: str, bounds: Sequence[float],
                 reg: "MetricsRegistry") -> None:
        b = tuple(float(x) for x in bounds)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(
                f"histogram {name!r} needs strictly increasing explicit "
                f"bucket bounds, got {b!r}")
        self.name = name
        self.bounds = b
        self._reg = reg
        self._cells: Dict[int, _HistCell] = {}

    def observe(self, v: float) -> None:
        cells = self._cells
        tid = threading.get_ident()
        cell = cells.get(tid)
        if cell is None:
            cell = cells[tid] = _HistCell(len(self.bounds) + 1)
        cell.counts[bisect_left(self.bounds, v)] += 1
        cell.total += v
        cell.n += 1
        sinks = self._reg._sinks_ro
        if sinks:
            t = now()
            for s in sinks:
                s.histogram(self.name, v, self.bounds, t)

    def aggregate(self) -> Dict[str, Any]:
        counts = [0] * (len(self.bounds) + 1)
        total = 0.0
        n = 0
        for cell in self._cells.copy().values():
            for i, c in enumerate(cell.counts):
                counts[i] += c
            total += cell.total
            n += cell.n
        return {"count": n, "sum": total, "bounds": list(self.bounds),
                "buckets": counts}

    @property
    def count(self) -> int:
        return sum(c.n for c in self._cells.copy().values())

    @property
    def sum(self) -> float:
        return sum(c.total for c in self._cells.copy().values())


class History:
    """Bounded ring of recent items (e.g. per-bucket occupancy curves).

    Appends are rare (once per bucket dispatch, not per observation) so
    a plain lock is fine; ``_items`` is guarded by ``_lock`` and covered
    by the lock-discipline scan.
    """

    __slots__ = ("name", "_lock", "_items")

    def __init__(self, name: str, maxlen: int) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._items: deque = deque(maxlen=int(maxlen))

    def append(self, item: Any) -> None:
        with self._lock:
            self._items.append(item)

    def snapshot(self) -> List[Any]:
        with self._lock:
            return list(self._items)

    @property
    def maxlen(self) -> int:
        with self._lock:
            return self._items.maxlen or 0


class MetricsRegistry:
    """Aggregating instrument registry with streaming sink fan-out.

    ``_lock`` guards the instrument table (``_instruments``) — i.e. the
    cold get-or-create path and ``snapshot()``.  Observations never
    enter the registry: instruments update their own lock-free cells and
    read the immutable ``_sinks_ro`` tuple directly (rebound whole under
    the lock by ``attach``; a plain attribute read is atomic).
    """

    LATENCY_BOUNDS = _DEFAULT_LATENCY_BOUNDS

    def __init__(self, sinks: Iterable[MetricsSink] = ()) -> None:
        self._lock = threading.Lock()
        self._instruments: Dict[str, Any] = {}
        self._sinks_ro: Tuple[MetricsSink, ...] = tuple(sinks)

    # -- sinks ---------------------------------------------------------
    def attach(self, sink: MetricsSink) -> None:
        with self._lock:
            self._sinks_ro = self._sinks_ro + (sink,)

    @property
    def sinks(self) -> Tuple[MetricsSink, ...]:
        return self._sinks_ro

    def emit(self, kind: str, payload: Dict[str, Any]) -> None:
        """Fan a structured event out to every sink."""
        for s in self._sinks_ro:
            s.event(kind, payload)

    # -- instruments ---------------------------------------------------
    def _get(self, name: str, cls, factory):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = self._instruments[name] = factory()
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"instrument {name!r} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name, self))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name, self))

    def histogram(self, name: str,
                  bounds: Sequence[float] = _DEFAULT_LATENCY_BOUNDS
                  ) -> Histogram:
        h = self._get(name, Histogram, lambda: Histogram(name, bounds, self))
        if h.bounds != tuple(float(x) for x in bounds):
            raise ValueError(
                f"histogram {name!r} already registered with bounds "
                f"{h.bounds!r}")
        return h

    def history(self, name: str, maxlen: int = 64) -> History:
        return self._get(name, History, lambda: History(name, maxlen))

    # -- views ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time value of every instrument, keyed by name."""
        with self._lock:
            items = list(self._instruments.items())
        out: Dict[str, Any] = {}
        for name, inst in items:
            if isinstance(inst, (Counter, Gauge)):
                out[name] = inst.value
            elif isinstance(inst, Histogram):
                out[name] = inst.aggregate()
            else:
                out[name] = inst.snapshot()
        return out

    def close(self) -> None:
        for s in self._sinks_ro:
            s.close()
