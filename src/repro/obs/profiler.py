"""Opt-in ``jax.profiler`` trace capture around a named dispatch.

Disarmed (the default) this is a single lock-guarded check per dispatch;
armed, the next dispatch whose name contains the match substring runs
under ``jax.profiler.trace`` writing a TensorBoard-loadable capture to
``<log_dir>/<sanitized name>``.  Arm programmatically::

    from repro.obs import profiler
    profiler.arm("/tmp/prof", match="bucket=64", captures=1)

or via the environment before the process starts::

    REPRO_PROFILE_DIR=/tmp/prof REPRO_PROFILE_MATCH= python ...

A capture failure (profiler unavailable, double-start, unwritable dir)
must never take down serving: the dispatch body always runs; failures
disarm the hook and are reported via ``logging`` only.
"""
from __future__ import annotations

import logging
import os
import re
import threading
from contextlib import contextmanager
from typing import Iterator, Optional

log = logging.getLogger("repro.obs.profiler")

_ENV_DIR = "REPRO_PROFILE_DIR"
_ENV_MATCH = "REPRO_PROFILE_MATCH"
_ENV_CAPTURES = "REPRO_PROFILE_CAPTURES"


class TraceCapture:
    """Armable one-(or-N-)shot profiler hook.

    ``_dir`` / ``_match`` / ``_remaining`` / ``_env_checked`` are guarded
    by ``_lock`` (covered by the lock-discipline scan): ``claim`` races
    against concurrent dispatch workers and must hand the capture to
    exactly one of them.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._dir: Optional[str] = None
        self._match: str = ""
        self._remaining: int = 0
        self._env_checked: bool = False

    def arm(self, log_dir: str, match: str = "", captures: int = 1) -> None:
        with self._lock:
            self._dir = str(log_dir)
            self._match = match
            self._remaining = int(captures)
            self._env_checked = True

    def disarm(self) -> None:
        with self._lock:
            self._dir = None
            self._match = ""
            self._remaining = 0
            self._env_checked = True

    def armed(self) -> bool:
        with self._lock:
            self._check_env_locked()
            return self._remaining > 0 and self._dir is not None

    def _check_env_locked(self) -> None:
        if self._env_checked:
            return
        self._env_checked = True
        d = os.environ.get(_ENV_DIR)
        if d:
            self._dir = d
            self._match = os.environ.get(_ENV_MATCH, "")
            self._remaining = int(os.environ.get(_ENV_CAPTURES, "1"))

    def claim(self, name: str) -> Optional[str]:
        """Atomically claim one capture slot for ``name``; returns the
        capture directory, or None if disarmed / name doesn't match."""
        with self._lock:
            self._check_env_locked()
            if self._remaining <= 0 or self._dir is None:
                return None
            if self._match and self._match not in name:
                return None
            self._remaining -= 1
            sub = re.sub(r"[^A-Za-z0-9._=-]+", "_", name) or "dispatch"
            return os.path.join(self._dir, sub)

    @contextmanager
    def capture(self, name: str) -> Iterator[bool]:
        """Run the body, profiling it iff a capture slot was claimed.

        Yields True when profiling is live.  Never raises on profiler
        failure — the body always executes exactly once.
        """
        d = self.claim(name)
        if d is None:
            yield False
            return
        ctx = None
        try:
            import jax
            ctx = jax.profiler.trace(d)
            ctx.__enter__()
        except Exception:
            log.warning("profiler capture %r failed to start; disarming",
                        name, exc_info=True)
            self.disarm()
            ctx = None
        try:
            yield ctx is not None
        finally:
            if ctx is not None:
                try:
                    ctx.__exit__(None, None, None)
                    log.info("profiler capture %r written to %s", name, d)
                except Exception:
                    log.warning("profiler capture %r failed to finalize",
                                name, exc_info=True)


#: Process-wide hook the serving stack checks around each named dispatch.
CAPTURE = TraceCapture()

arm = CAPTURE.arm
disarm = CAPTURE.disarm
armed = CAPTURE.armed
capture = CAPTURE.capture
