"""repro.obs — live serving observability.

Three pieces, all import-light (stdlib only at import time):

* :mod:`repro.obs.metrics` — the ``MetricsSink`` protocol (counters,
  gauges, histograms with explicit bucket bounds) with in-memory, JSONL,
  and logging implementations, plus ``MetricsRegistry``, an aggregating
  registry that is lock-free on the observation hot path.
* :mod:`repro.obs.tracing` — hierarchical ``Span``s on the monotonic
  clock with per-request trace ids, emitted as structured events
  covering submit → admission → collate → bucket dispatch → per-chunk
  solve → artifact fetch (plus the fault events: retries, ladder level,
  quarantine, deadline cuts, degraded answers).
* :mod:`repro.obs.profiler` — an opt-in ``jax.profiler`` trace-capture
  hook around a named dispatch.

``now()`` is the one monotonic clock shared by spans, deadlines, and
wait/solve stats across ``serve/`` and the chunked drivers.
"""
from . import profiler
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    History,
    InMemorySink,
    JSONLSink,
    LoggingSink,
    MetricsRegistry,
    MetricsSink,
    NullSink,
    jsonable,
    now,
)
from .tracing import Span, Tracer, new_id, span_tree

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "History",
    "InMemorySink",
    "JSONLSink",
    "LoggingSink",
    "MetricsRegistry",
    "MetricsSink",
    "NullSink",
    "Span",
    "Tracer",
    "jsonable",
    "new_id",
    "now",
    "profiler",
    "span_tree",
]
