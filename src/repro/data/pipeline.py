"""Deterministic, stateless-resumable synthetic data pipeline.

Every batch is a pure function of (seed, step) - a restarted trainer resumes
at step k and sees byte-identical data with zero pipeline state in the
checkpoint. Sharding: the host builds global arrays; jit in_shardings split
them across ('pod','data'). A background prefetch thread keeps `depth`
batches ahead so host-side generation overlaps device compute (straggler
mitigation lever #1)."""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import jax.numpy as jnp
import numpy as np


def synthetic_batch(cfg, seq_len: int, batch: int, *, seed: int, step: int,
                    kind: str = "train") -> Dict[str, np.ndarray]:
    """Markov-ish token streams (so loss decreases measurably), plus stub
    modality embeddings where the architecture needs them."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(step))
    n_tok = seq_len + 1 if kind == "train" else seq_len
    v = cfg.vocab_size
    # low-order Markov structure: next = (prev * a + noise) % v
    base = rng.integers(0, v, size=(batch, 1))
    steps = rng.integers(0, 17, size=(batch, n_tok))
    toks = (base + np.cumsum(steps, axis=1)) % v
    out: Dict[str, np.ndarray] = {"tokens": toks.astype(np.int32)}
    if cfg.input_mode == "frames":
        out["frames"] = rng.standard_normal(
            (batch, seq_len, cfg.d_model), dtype=np.float32
        ).astype(jnp.bfloat16)
    if cfg.input_mode == "tokens+patches":
        out["patches"] = rng.standard_normal(
            (batch, cfg.num_patch_tokens, cfg.d_model), dtype=np.float32
        ).astype(jnp.bfloat16)
        n = max(seq_len - cfg.num_patch_tokens, 8)
        out["tokens"] = out["tokens"][:, : n + 1 if kind == "train" else n]
    return out


class Prefetcher:
    """Background thread that stays `depth` steps ahead of the consumer."""

    def __init__(self, cfg, seq_len, batch, *, seed, start_step=0, depth=2,
                 kind="train"):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = synthetic_batch(cfg, seq_len, batch, seed=seed,
                                    step=step, kind=kind)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
